"""The paper's §1 isolation claim, demonstrated: AVX-induced frequency
reduction forms a covert channel between otherwise isolated processes —
and core specialization closes it.

Without specialization, sender and receiver time-share a core. The sender
holds each bit for one 2.5 ms window: a '1' window repeats dense AVX-512
bursts (the 2 ms license tail keeps the core at the reduced frequency),
a '0' window is pure scalar. The receiver times short scalar probes; a
'1' window makes them ~32% slower. With core specialization the sender
(an AVX task) is confined to the AVX core and the receiver's scalar core
never changes frequency — the channel reads noise.

  PYTHONPATH=src python examples/covert_channel.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro.core.muqss import SchedConfig  # noqa: E402
from repro.core.simulator import RequestDone, Simulator  # noqa: E402
from repro.core.task import IClass, Segment, Task, TaskType  # noqa: E402

SLOT_US = 250.0          # scheduler slot
BIT_SLOTS = 10           # 2.5 ms per bit (> 2 ms hysteresis)
F0 = 2.8e3               # cycles/us at L0


def sender(bits):
    for b in bits:
        for _ in range(BIT_SLOTS):
            if b:
                yield Segment(0.3 * SLOT_US * 1.9e3, IClass.AVX512,
                              dense=True, stack=("sender", "avx_burst"))
                yield Segment(0.7 * SLOT_US * F0, IClass.SCALAR,
                              stack=("sender", "pad"))
            else:
                yield Segment(SLOT_US * F0, IClass.SCALAR,
                              stack=("sender", "pad"))
        yield RequestDone()


def receiver(n_probes, probe_cycles):
    for _ in range(n_probes):
        yield Segment(probe_cycles, IClass.SCALAR,
                      stack=("receiver", "probe"))
        yield RequestDone()


def run(spec: bool, bits):
    if spec:
        scfg = SchedConfig(n_cores=2, n_avx_cores=1, specialization=True,
                           rr_interval_us=SLOT_US)
    else:
        scfg = SchedConfig(n_cores=1, n_avx_cores=0, specialization=False,
                           rr_interval_us=SLOT_US)
    sim = Simulator(scfg)
    probe = 0.9 * SLOT_US * F0
    total_us = len(bits) * BIT_SLOTS * SLOT_US * (2.2 if not spec else 1.2)
    s = Task(sender(bits), name="sender",
             ttype=TaskType.AVX if spec else TaskType.SCALAR)
    r = Task(receiver(int(total_us / SLOT_US) + 8, probe),
             ttype=TaskType.SCALAR, name="receiver")
    sim.add_task(s, 0.0)
    sim.add_task(r, 1.0)
    sim.run(total_us)
    probes = [(t, lat) for t, lat, name in sim.metrics.completions
              if name == "receiver"]
    sends = [t for t, _, name in sim.metrics.completions
             if name == "sender"]
    return probes, sends


def decode(probes, sends, bits):
    """Average probe latency inside each sender bit window."""
    if len(sends) < len(bits):
        bits = bits[:len(sends)]
    starts = [0.0] + sends[:-1]
    means = []
    for s0, s1 in zip(starts, sends):
        xs = [lat for t, lat in probes if s0 < t <= s1]
        means.append(np.mean(xs) if xs else 0.0)
    means = np.asarray(means)
    thresh = np.median(means)
    decoded = (means > thresh).astype(int)
    return float((decoded == np.asarray(bits)).mean())


def main():
    rng = np.random.default_rng(0)
    bits = list(rng.integers(0, 2, size=64))
    accs = {}
    for spec in (False, True):
        probes, sends = run(spec, bits)
        acc = decode(probes, sends, bits)
        accs[spec] = acc
        mode = "with specialization" if spec else "no specialization"
        print(f"{mode:22s}: covert-channel decode accuracy {acc*100:5.1f}% "
              f"({'OPEN' if acc > 0.75 else 'closed'})")
    print("\n-> the frequency side channel is readable without "
          "specialization and closed by it (paper §1, isolation breach).")
    return accs


if __name__ == "__main__":
    main()
