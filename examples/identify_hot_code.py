"""The paper's §3.3 identification workflow, end to end:

 1. static analysis  — segment each function into a region timeline
                       (scalar / wide-vector / MXU license classes; the
                       x86 tool ranked by 256/512-bit register use) and
                       rank functions by heavy-op density;
 2. perf counters    — run the workload in the simulator and build the
                       CORE_POWER.THROTTLE flame graph;
 3. cross-check      — intersect the two to drop trailing-code false
                       positives;
 4. annotate         — the survivors are the code to wrap in
                       with_avx()/without_avx() (here: tag as heavy phase).

  PYTHONPATH=src python examples/identify_hot_code.py
"""
import sys

sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.analysis import (  # noqa: E402
    FunctionProfile, rank_functions, report, segment, tag_heavy)
from repro.core.muqss import SchedConfig  # noqa: E402
from repro.core.perfcounters import collect, cross_check  # noqa: E402
from repro.core.simulator import Simulator  # noqa: E402
from repro.core.workloads import WebConfig, webserver_tasks  # noqa: E402
from repro.sched import Topology, make_policy  # noqa: E402


def main(sim_us: float = 300_000.0):
    # ---- 1. static analysis over the application's functions ----------
    d, ff = 256, 1024
    w1 = jnp.zeros((d, ff))
    w2 = jnp.zeros((ff, d))

    def chacha20_avx512(x):        # vectorized crypto: pure ALU stream
        for _ in range(8):
            x = (x << 7) ^ (x >> 3) + x
        return x

    def brotli(x):                 # compression: branchy scalar-ish work
        return jnp.cumsum(jnp.tanh(x) * 0.5, axis=-1)

    def ffn_block(x):              # MXU-dense (the TPU heavy class)
        return jax.nn.gelu(x @ w1) @ w2

    # region timelines: program-order phases with license classes —
    # sub-function granularity the old whole-function ranking lacked
    timelines = [
        segment(chacha20_avx512, jnp.zeros((64, d), jnp.int32),
                name="chacha20_avx512"),
        segment(brotli, jnp.zeros((64, d)), name="brotli"),
        segment(ffn_block, jnp.zeros((64, d)), name="ffn_block"),
    ]
    print("== region timelines (program-order phase segmentation) ==")
    for tl in timelines:
        print(tl.report())
        print()
    print("analyzer heavy tags:", tag_heavy(timelines))

    ranked = rank_functions([
        ("chacha20_avx512", chacha20_avx512,
         (jnp.zeros((64, d), jnp.int32),)),
        ("brotli", brotli, (jnp.zeros((64, d)),)),
        ("ffn_block", ffn_block, (jnp.zeros((64, d)),)),
    ])
    print("\n== whole-function ranking (sorted by heavy-op ratio) ==")
    print(report(ranked))

    # ---- 2. perf-counter pass in the simulator ------------------------
    # The unified repro.sched API: an explicit one-pool Topology and a
    # registry policy, not the pre-PR-2 config flags.
    print("\n== CORE_POWER.THROTTLE flame graph (folded stacks) ==")
    sim = Simulator(SchedConfig(n_cores=12, n_avx_cores=0,
                                specialization=False),
                    topology=Topology.shared(12),
                    policy=make_policy("shared"))
    for t in webserver_tasks(WebConfig(isa="avx512")):
        sim.add_task(t)
    sim.run(sim_us)
    rep = collect(sim)
    print(rep.folded("throttle")[:800])
    print("\nlicense residency:", {k: round(v, 3)
                                   for k, v in rep.license_residency().items()})
    print("top throttle culprits:", rep.culprits(3))

    # ---- 3. cross-check to drop false positives -----------------------
    static_for_sim = [
        FunctionProfile("chacha20_avx512", 9, 10, 1),   # dense heavy
        FunctionProfile("brotli", 0, 10, 1),            # scalar
    ]
    confirmed = cross_check(rep, static_for_sim)
    print("\n== cross-check: annotate these ==")
    print(confirmed)
    assert any("chacha20" in c for c in confirmed)
    assert not any("brotli" in c for c in confirmed)
    print("\n(nginx prototype: 9 annotation lines around SSL_read/SSL_write/"
          "SSL_do_handshake/SSL_shutdown — paper §4)")
    return confirmed


if __name__ == "__main__":
    main()
