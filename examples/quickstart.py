"""Quickstart: train a small LM end-to-end with checkpoint/resume.

  PYTHONPATH=src python examples/quickstart.py            # ~2 min on CPU
  PYTHONPATH=src python examples/quickstart.py --full     # ~100M params,
                                                          # a few hundred steps

The full variant is the deliverable-(b) end-to-end driver: ~100M-param
model, few hundred steps; expect ~15 s/step on one CPU core.
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch.train import main as train_main  # noqa: E402

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args, _ = ap.parse_known_args()
    if args.full:
        # qwen1.5-0.5b width with 4 layers ~ 105M non-embedding+embedding
        train_main(["--arch", "qwen1.5-0.5b", "--steps", "300",
                    "--n-layers", "4", "--data-order", "1",
                    "--batch", "4", "--seq", "512", "--grad-accum", "2",
                    "--lr", "1e-2",
                    "--ckpt-dir", "/tmp/repro_quickstart_full",
                    "--ckpt-every", "50"])
    else:
        train_main(["--arch", "qwen1.5-0.5b", "--reduced", "--steps", "200",
                    "--batch", "8", "--seq", "128", "--lr", "1e-2",
                    "--data-order", "1",
                    "--ckpt-dir", "/tmp/repro_quickstart",
                    "--ckpt-every", "50"])
